"""Kernel-layer microbenchmarks.

On this CPU container the Pallas kernels execute in interpret mode (Python),
so wall-clock numbers compare the XLA *unfused* update against an XLA
*pre-fused* single-expression update (the computation the Pallas kernel
performs per tile); the kernel's HBM-byte advantage is reported
analytically from the operand counts (DESIGN.md §5: 32 B/elem fused vs
>= 52 B/elem naive with materialized m_hat/v_hat).

The ``uploadfuse_dp_int4`` row measures the one-pass DP + int4 upload
(the uploadfuse megakernel's computation) against the staged engine
path including the codec wire round trip it eliminates."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Rows, budget, print_table


def _timeit(fn, *args, iters=20):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return 1e6 * (time.perf_counter() - t0) / iters


def run() -> Rows:
    rows = Rows("kernels_bench")
    n = budget(1 << 22, 1 << 18)
    rng = np.random.default_rng(0)
    x, g, m, v, dg = [jnp.asarray(rng.normal(size=(n,)), jnp.float32)
                      for _ in range(5)]
    v = jnp.abs(v)

    @jax.jit
    def unfused(x, g, m, v, dg):
        # separate kernels the way a naive implementation materializes them
        m2 = 0.9 * m + 0.1 * g
        v2 = 0.999 * v + 0.001 * g * g
        mhat = m2 / 0.1
        vhat = v2 / 0.00799
        step = mhat / (jnp.sqrt(vhat) + 1e-8) + 0.5 * dg + 0.01 * x
        return x - 3e-4 * step, m2, v2

    @jax.jit
    def fused(x, g, m, v, dg):
        # single expression == what the Pallas kernel computes per tile
        m2 = 0.9 * m + 0.1 * g
        v2 = 0.999 * v + 0.001 * g * g
        return (x - 3e-4 * ((m2 / 0.1) / (jnp.sqrt(v2 / 0.00799) + 1e-8)
                            + 0.5 * dg + 0.01 * x), m2, v2)

    t_unfused = _timeit(unfused, x, g, m, v, dg)
    t_fused = _timeit(fused, x, g, m, v, dg)
    rows.add(kernel="fused_adamw", n_elems=n,
             xla_unfused_us=round(t_unfused, 1),
             xla_fused_us=round(t_fused, 1),
             pallas_bytes_per_elem=32,
             naive_bytes_per_elem=52)

    # blockmean: column mean with transpose vs direct reduction
    r, c = budget(4096, 512), budget(2048, 256)
    xx = jnp.asarray(rng.normal(size=(r, c)), jnp.float32)

    @jax.jit
    def xla_colmean(x):
        return x.mean(axis=0)

    t_col = _timeit(xla_colmean, xx)
    rows.add(kernel="blockmean", n_elems=r * c,
             xla_unfused_us=round(t_col, 1), xla_fused_us=round(t_col, 1),
             pallas_bytes_per_elem=4, naive_bytes_per_elem=8)

    # uploadfuse: the DP + int4 upload (fold -> clip -> quantize-pack ->
    # wire -> unpack -> re-clip -> accumulate) as one XLA expression vs
    # the staged jits the unfused engine runs — including the codec wire
    # round trip the fused kernel skips (it aggregates decoded values
    # in-register and emits packed codes as a side output). The barrier
    # in the one-pass program pins the decoded copy to a single
    # materialization, exactly like the kernel's per-tile compute —
    # without it XLA re-derives the decode chain for each consumer.
    s_n, r_u, c_u = 4, budget(512, 64), 1024

    def _clip05(a):
        norm = jnp.sqrt(jnp.sum(a * a, axis=(1, 2)))
        return jnp.minimum(1.0, 0.5 / jnp.maximum(norm, 1e-12)
                           )[:, None, None] * a

    def _scale4(ctgt):
        return jnp.maximum(jnp.max(jnp.abs(ctgt), axis=(1, 2)),
                           1e-12)[:, None, None] / 7.0

    def _pack(q):
        c8 = (q + 8.0).astype(jnp.uint8)
        pairs = c8.reshape(*c8.shape[:-1], -1, 2)
        return pairs[..., 0] | (pairs[..., 1] << 4)

    def _unpack(p, sc):
        lo = (p & 0xF).astype(jnp.float32) - 8.0
        hi = (p >> 4).astype(jnp.float32) - 8.0
        q = jnp.stack([lo, hi], axis=-1).reshape(*p.shape[:-1], -1)
        return q * sc

    stage_fold = jax.jit(lambda x, e: x + e)
    stage_clip = jax.jit(_clip05)
    stage_scale = jax.jit(_scale4)
    stage_q = jax.jit(lambda ctgt, u, sc: jnp.clip(
        jnp.floor(ctgt / sc + u), -8.0, 7.0))
    stage_pack = jax.jit(_pack)
    stage_unpack = jax.jit(_unpack)
    stage_acc = jax.jit(lambda w, final: jnp.sum(
        w[:, None, None] * final, axis=0))
    stage_res = jax.jit(lambda ctgt, final: ctgt - final)

    def staged(x, e, u, w):
        ctgt = stage_clip(stage_fold(x, e))
        sc = stage_scale(ctgt)
        q = stage_q(ctgt, u, sc)
        wire = stage_pack(q)               # client encode -> wire
        final = stage_clip(stage_unpack(wire, sc))   # server decode
        return stage_acc(w, final), stage_res(ctgt, final), wire

    @jax.jit
    def onepass(x, e, u, w):
        ctgt = _clip05(x + e)
        sc = _scale4(ctgt)
        q = jnp.clip(jnp.floor(ctgt / sc + u), -8.0, 7.0)
        final = jax.lax.optimization_barrier(_clip05(q * sc))
        return (jnp.sum(w[:, None, None] * final, axis=0),
                ctgt - final, _pack(q))

    xu, eu, uu = [jnp.asarray(rng.normal(size=(s_n, r_u, c_u)),
                              jnp.float32) for _ in range(3)]
    wu = jnp.full((s_n,), 1.0 / s_n, jnp.float32)
    t_staged = _timeit(staged, xu, eu, uu, wu)
    t_onepass = _timeit(onepass, xu, eu, uu, wu)
    for a, b in zip(staged(xu, eu, uu, wu), onepass(xu, eu, uu, wu)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    rows.add(kernel="uploadfuse_dp_int4", n_elems=s_n * r_u * c_u,
             xla_unfused_us=round(t_staged, 1),
             xla_fused_us=round(t_onepass, 1),
             pallas_bytes_per_elem=17,    # x+e+u in, acc/S+res+codes out
             naive_bytes_per_elem=41)     # + ctgt/dec/wire round trips

    # correctness cross-check against the Pallas kernels (interpret mode)
    from repro.kernels.blockmean.ops import block_means_2d
    from repro.kernels.blockmean.ref import column_mean_ref
    small = xx[:256, :128]
    np.testing.assert_allclose(np.asarray(block_means_2d(small)),
                               np.asarray(column_mean_ref(small)),
                               rtol=1e-5, atol=1e-6)
    rows.save()
    print_table("Kernels — fused optimizer update & block-mean", rows.rows)
    return rows


if __name__ == "__main__":
    run()
