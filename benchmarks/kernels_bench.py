"""Kernel-layer microbenchmarks.

On this CPU container the Pallas kernels execute in interpret mode (Python),
so wall-clock numbers compare the XLA *unfused* update against an XLA
*pre-fused* single-expression update (the computation the Pallas kernel
performs per tile); the kernel's HBM-byte advantage is reported
analytically from the operand counts (DESIGN.md §5: 32 B/elem fused vs
>= 52 B/elem naive with materialized m_hat/v_hat)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Rows, budget, print_table


def _timeit(fn, *args, iters=20):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return 1e6 * (time.perf_counter() - t0) / iters


def run() -> Rows:
    rows = Rows("kernels_bench")
    n = budget(1 << 22, 1 << 18)
    rng = np.random.default_rng(0)
    x, g, m, v, dg = [jnp.asarray(rng.normal(size=(n,)), jnp.float32)
                      for _ in range(5)]
    v = jnp.abs(v)

    @jax.jit
    def unfused(x, g, m, v, dg):
        # separate kernels the way a naive implementation materializes them
        m2 = 0.9 * m + 0.1 * g
        v2 = 0.999 * v + 0.001 * g * g
        mhat = m2 / 0.1
        vhat = v2 / 0.00799
        step = mhat / (jnp.sqrt(vhat) + 1e-8) + 0.5 * dg + 0.01 * x
        return x - 3e-4 * step, m2, v2

    @jax.jit
    def fused(x, g, m, v, dg):
        # single expression == what the Pallas kernel computes per tile
        m2 = 0.9 * m + 0.1 * g
        v2 = 0.999 * v + 0.001 * g * g
        return (x - 3e-4 * ((m2 / 0.1) / (jnp.sqrt(v2 / 0.00799) + 1e-8)
                            + 0.5 * dg + 0.01 * x), m2, v2)

    t_unfused = _timeit(unfused, x, g, m, v, dg)
    t_fused = _timeit(fused, x, g, m, v, dg)
    rows.add(kernel="fused_adamw", n_elems=n,
             xla_unfused_us=round(t_unfused, 1),
             xla_fused_us=round(t_fused, 1),
             pallas_bytes_per_elem=32,
             naive_bytes_per_elem=52)

    # blockmean: column mean with transpose vs direct reduction
    r, c = budget(4096, 512), budget(2048, 256)
    xx = jnp.asarray(rng.normal(size=(r, c)), jnp.float32)

    @jax.jit
    def xla_colmean(x):
        return x.mean(axis=0)

    t_col = _timeit(xla_colmean, xx)
    rows.add(kernel="blockmean", n_elems=r * c,
             xla_unfused_us=round(t_col, 1), xla_fused_us=round(t_col, 1),
             pallas_bytes_per_elem=4, naive_bytes_per_elem=8)

    # correctness cross-check against the Pallas kernels (interpret mode)
    from repro.kernels.blockmean.ops import block_means_2d
    from repro.kernels.blockmean.ref import column_mean_ref
    small = xx[:256, :128]
    np.testing.assert_allclose(np.asarray(block_means_2d(small)),
                               np.asarray(column_mean_ref(small)),
                               rtol=1e-5, atol=1e-6)
    rows.save()
    print_table("Kernels — fused optimizer update & block-mean", rows.rows)
    return rows


if __name__ == "__main__":
    run()
