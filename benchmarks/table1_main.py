"""Paper Table 1/11: all 8 algorithms under Dir-0.6 and Dir-0.1 on the
ViT-Tiny analogue. Reproduces the RELATIVE ordering (FedAdamW best) on the
synthetic non-iid task — absolute CIFAR accuracies are out of scope on CPU
(DESIGN.md §6)."""
from benchmarks.common import Rows, bench_fl, print_table

ALGOS = ["fedavg", "scaffold", "fedcm", "local_adam", "fedadam",
         "fedlada", "local_adamw", "fedadamw"]


def run() -> Rows:
    rows = Rows("table1_main")
    for dirichlet in (0.6, 0.1):
        for algo in ALGOS:
            h = bench_fl(algo, dirichlet=dirichlet)
            rows.add(algorithm=algo, dirichlet=dirichlet,
                     test_acc=round(h["test_acc"][-1], 4),
                     train_loss=round(h["train_loss"][-1], 4),
                     comm_mb=round(h["upload_mbytes"][-1], 3))
    rows.save()
    print_table("Table 1 — main comparison (synthetic, 2 heterogeneity "
                "levels)", rows.rows)
    return rows


if __name__ == "__main__":
    run()
