"""Paper Table 6: weight-decay sweep. Local Adam (coupled L2) collapses at
large lambda; decoupled AdamW variants stay stable; FedAdamW best."""
from benchmarks.common import Rows, bench_fl, print_table


def run() -> Rows:
    rows = Rows("table6_weight_decay")
    for lam in (0.001, 0.01, 0.1):
        for algo in ("local_adam", "local_adamw", "fedadamw"):
            h = bench_fl(algo, dirichlet=0.1, weight_decay=lam)
            rows.add(algorithm=algo, weight_decay=lam,
                     test_acc=round(h["test_acc"][-1], 4),
                     train_loss=round(h["train_loss"][-1], 4))
    rows.save()
    print_table("Table 6 — weight decay sweep (Dir-0.1)", rows.rows)
    return rows


if __name__ == "__main__":
    run()
