"""Beyond-paper extensions bench: FedLAMB / FedLion (the optimizers the
paper's conclusion points at) and int8-quantized uploads, against
FedAdamW — accuracy and wire bytes."""
import jax

from benchmarks.common import Rows, bench_fl, print_table
from repro.comm import codec_for, upload_wire_bytes
from repro.core import build_fed_state, upload_shape_spec
from repro.config import FedConfig, get_arch
from repro.config.model_config import reduced_variant
from repro.models import build_model


def _wire_mb(algorithm: str) -> float:
    import jax.numpy as jnp
    cfg = reduced_variant(get_arch("vit-tiny-fl"))
    model = build_model(cfg, compute_dtype=jnp.float32)
    fed = FedConfig(algorithm=algorithm, num_clients=4, clients_per_round=2,
                    local_steps=1)
    params, specs, alg, sstate = build_fed_state(
        model, fed, jax.random.key(0), cfg=cfg)
    spec = upload_shape_spec(alg, params, sstate, specs, fed)
    return upload_wire_bytes(spec, codec_for(algorithm)) / 1e6


def run() -> Rows:
    rows = Rows("beyond_paper")
    for algo, lr in (("fedadamw", None), ("fedlamb", None),
                     ("fedlion", 1e-4), ("fedadamw+int8", None)):
        h = bench_fl(algo, dirichlet=0.1, lr=lr)
        rows.add(algorithm=algo,
                 test_acc=round(h["test_acc"][-1], 4),
                 train_loss=round(h["train_loss"][-1], 4),
                 wire_mb_per_client=round(_wire_mb(algo), 3))
    rows.save()
    print_table("Beyond paper — FedLAMB / FedLion / int8 uploads",
                rows.rows)
    return rows


if __name__ == "__main__":
    run()
