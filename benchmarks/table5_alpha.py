"""Paper Table 5: sweep of the global-update correction strength alpha."""
from benchmarks.common import Rows, bench_fl, print_table


def run() -> Rows:
    rows = Rows("table5_alpha")
    for alpha in (0.0, 0.25, 0.5, 0.75, 1.0):
        h = bench_fl("fedadamw", dirichlet=0.1, alpha=alpha)
        rows.add(alpha=alpha, test_acc=round(h["test_acc"][-1], 4),
                 train_loss=round(h["train_loss"][-1], 4))
    rows.save()
    print_table("Table 5 — alpha sweep (Dir-0.1)", rows.rows)
    return rows


if __name__ == "__main__":
    run()
