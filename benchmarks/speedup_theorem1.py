"""Theorem 1 linear-speedup check: the convergence rate improves with the
product S*K (participating clients x local steps). We fix the total
gradient budget per round and report training loss after a fixed number of
rounds for increasing S*K."""
from benchmarks.common import Rows, bench_fl, budget, print_table


def run() -> Rows:
    rows = Rows("speedup_theorem1")
    for s, k in ((2, 2), (4, 4), (8, 8)):
        h = bench_fl("fedadamw", dirichlet=0.6,
                     num_clients=max(8, s), clients_per_round=s,
                     local_steps=k, rounds=budget(10, 2))
        rows.add(S=s, K=k, SK=s * k,
                 train_loss=round(h["train_loss"][-1], 4),
                 test_acc=round(h["test_acc"][-1], 4))
    rows.save()
    print_table("Theorem 1 — loss after fixed rounds vs S*K", rows.rows)
    return rows


if __name__ == "__main__":
    run()
